"""Sharded-scrub throughput: words scrubbed/sec vs host-device count 1 -> 8.

Benchmarks the shard_map'd paged scrub-on-read step (distributed/meshrel.py):
every reliability shard gathers its own page rows from its slice of the
stacked KV planes, runs the Hsiao scrub kernel, and writes corrected planes
back — no plane word crosses a shard, so throughput should scale with the
shard count until the host runs out of cores. Each device count runs in its
own subprocess (``--xla_force_host_platform_device_count`` is locked at jax
init), timed after a warmup call.

CSV rows: ``mesh_scrub_d<N>,us_per_call,words_per_s=...`` plus the scaling
summary row the nightly trajectory tracks.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import csv_line, emit

DEFAULT_DEVICES = (1, 2, 4, 8)


def _worker(n_devices: int, n_pages: int, page_words: int, repeat: int) -> None:
    """Runs inside a subprocess with ``n_devices`` forced host devices."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.distributed import meshrel
    from repro.launch.mesh import make_reliability_mesh

    assert len(jax.devices()) == n_devices, (len(jax.devices()), n_devices)
    mesh = make_reliability_mesh(n_devices)
    sharding = meshrel.arena_sharding(mesh)
    local_words = n_pages * page_words
    total = n_devices * local_words
    rng = np.random.default_rng(0)
    lo = jax.device_put(
        jnp.asarray(rng.integers(0, 1 << 32, size=total, dtype=np.uint32)), sharding
    )
    hi = jax.device_put(
        jnp.asarray(rng.integers(0, 1 << 32, size=total, dtype=np.uint32)), sharding
    )
    from repro.kernels import ops as kops

    par = jax.device_put(kops.encode(lo, hi), sharding)
    # every shard scrubs all of its local pages each call
    table = jax.device_put(
        jnp.tile(jnp.arange(n_pages, dtype=jnp.int32)[None], (n_devices, 1)),
        jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data")),
    )
    step = meshrel.make_kv_scrub_step(mesh, page_words, local_words, n_pages)
    olo, ohi, opar, _, _, cnt = step(lo, hi, par, table)
    jax.block_until_ready(cnt)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        olo, ohi, opar, _, _, cnt = step(lo, hi, par, table)
        jax.block_until_ready(cnt)
    us = (time.perf_counter() - t0) / repeat * 1e6
    print(json.dumps({
        "devices": n_devices,
        "us_per_call": us,
        "words_scrubbed": total,
        "words_per_s": total / (us / 1e6),
        "clean_words": int(np.asarray(cnt)[..., 0].sum()),
    }))


def run_points(devices, n_pages: int, page_words: int, repeat: int) -> list[dict]:
    rows = []
    for n in devices:
        env = dict(os.environ)
        # preserve unrelated XLA flags; only the forced device count is ours
        kept = [
            f for f in env.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")
        ]
        env["XLA_FLAGS"] = " ".join(
            kept + [f"--xla_force_host_platform_device_count={n}"]
        )
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (
                os.path.join(os.path.dirname(__file__), "..", "src"),
                os.path.join(os.path.dirname(__file__), ".."),
                env.get("PYTHONPATH", ""),
            ) if p
        )
        out = subprocess.run(
            [
                sys.executable, "-m", "benchmarks.sharded_scrub",
                "--worker", "--devices", str(n), "--pages", str(n_pages),
                "--page-words", str(page_words), "--repeat", str(repeat),
            ],
            capture_output=True, text=True, env=env, timeout=900,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        rows.append(json.loads(out.stdout.strip().splitlines()[-1]))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--devices", type=int, default=0,
                    help="single device count (worker / one-point mode)")
    ap.add_argument("--max-devices", type=int, default=8)
    ap.add_argument("--pages", type=int, default=16)
    ap.add_argument("--page-words", type=int, default=2048)
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometry (CI: exercise the path, not the clock)")
    # parse_known_args: benchmarks.run passes its section name through argv
    args, _ = ap.parse_known_args(argv)
    if args.smoke:
        args.pages, args.page_words, args.repeat = 4, 512, 1
    if args.worker:
        _worker(args.devices, args.pages, args.page_words, args.repeat)
        return
    devices = [n for n in DEFAULT_DEVICES if n <= args.max_devices]
    if args.devices:
        devices = [args.devices]
    rows = run_points(devices, args.pages, args.page_words, args.repeat)
    for r in rows:
        print(csv_line(
            f"mesh_scrub_d{r['devices']}", r["us_per_call"],
            f"words_per_s={r['words_per_s']:.3e}",
        ))
    if len(rows) > 1:
        scale = rows[-1]["words_per_s"] / rows[0]["words_per_s"]
        print(csv_line(
            f"mesh_scrub_scaling_{rows[0]['devices']}to{rows[-1]['devices']}",
            0.0, f"throughput_ratio={scale:.2f}",
        ))
    emit(rows, "sharded_scrub")


if __name__ == "__main__":
    main()
