"""Serve a small LM with batched requests under an undervolted, ECC-protected
weight memory — the paper's technique as a first-class serving feature.

* Weights are int8-quantized, packed to BRAM word geometry, SECDED-encoded
  (`inline` mode: every matmul runs the fused Pallas decode read path).
* The engine scrubs fault telemetry between rounds and the DED-canary
  controller walks the rail down until the first detected-uncorrectable
  event (paper §III/IV runtime undervolting).
* Output-token agreement vs the clean model + modeled power are reported at
  each voltage.

Run: PYTHONPATH=src python examples/serve_lm_ecc.py
"""

import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving import (
    FaultModelConfig,
    ProtectionConfig,
    RailsConfig,
    ReliabilityConfig,
    ServingEngine,
)

import jax


def main():
    cfg = get_smoke_config("qwen3-0.6b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(4, 8)).astype(np.int32)

    clean = ServingEngine(cfg, params, rel=None, max_len=64)
    ref_out = clean.generate(prompts, n_tokens=24)

    print("batched generation under undervolting (inline SECDED weights):")
    print(f"{'V':>5} | {'agree':>6} | {'corrected':>9} | {'detected':>8} | {'power W':>8}")
    for v in (1.0, 0.58, 0.56, 0.54):
        eng = ServingEngine(
            cfg, params,
            rel=ReliabilityConfig(platform="vc707", ecc=True, voltage=v, mode="inline"),
            max_len=64,
        )
        out = eng.generate(prompts, n_tokens=24)
        agree = float((out == ref_out).mean())
        s = eng.stats
        print(f"{v:5.2f} | {100 * agree:5.1f}% | {s.corrected:9d} | {s.detected:8d} "
              f"| {eng.power_w():8.2f}")

    # Runtime undervolting: find the minimum safe voltage via the DED canary.
    eng = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(platform="vc707", ecc=True, voltage=1.0, mode="inline"),
        max_len=64,
    )
    v_safe, history = eng.autotune_voltage()
    out = eng.generate(prompts, n_tokens=24)
    agree = float((out == ref_out).mean())
    print(
        f"\nDED-canary controller locked at {v_safe:.2f} V after {len(history)} rounds; "
        f"token agreement at locked voltage: {100 * agree:.1f}%; "
        f"accelerator power {eng.power_w():.2f} W (nominal {ServingEngine(cfg, params, rel=ReliabilityConfig(voltage=1.0)).power_w():.2f} W)"
    )

    # Multi-rail: embedding / attention / MLP each walk their own rail down
    # to their own first-DED point (DESIGN.md §10). The single rail above had
    # to stop at the weakest domain's trip voltage; the per-domain schedule
    # recovers the rest of the headroom.
    multi = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(
            platform="vc707", ecc=True, voltage=1.0, mode="inline",
            rails=RailsConfig(multi_rail=True, start_v=0.62),
        ),
        max_len=64,
    )
    volts, rail_hist = multi.autotune_voltage()
    report = multi.power_report()
    rails = ", ".join(f"{d}={v:.2f}V" for d, v in sorted(volts.items()))
    print(f"\nmulti-rail locks: {rails}")
    print(
        f"BRAM power {report['bram_w'] * 1e3:.0f} mW "
        f"({100 * report['saving_vs_nominal']:.1f}% saving vs nominal; "
        f"single-rail at {v_safe:.2f} V saved "
        f"{100 * eng.power_report()['saving_vs_nominal']:.1f}%)"
    )
    for d, st in multi.rail_stats.by_domain.items():
        print(f"  {d:>10}: corrected={st.corrected} detected={st.detected} "
              f"silent={st.silent} over {st.words} scrubbed words")

    # Continuous batching over the paged SECDED KV cache (DESIGN.md §11):
    # a stream of variable-length requests served on 2 lanes; every token's
    # KV is committed to ECC pages on the `kv` domain, scrubbed on read, and
    # the per-page DED counters walk the kv rail down to its own lock —
    # independent of the weight rails locked above.
    print("\ncontinuous batching on the paged SECDED KV cache:")
    stream = [
        (prompts[i % 4][: 4 + (3 * i) % 5], 6 + (7 * i) % 13) for i in range(6)
    ]
    report = multi.serve(
        stream, n_lanes=2, page_tokens=8, scrub_interval=4,
        walk_kv=True, kv_voltage=0.60,
    )
    kv_rail = multi.controller.rails["kv"]
    print(
        f"served {len(report.outputs)} requests in {report.steps} decode steps "
        f"({report.preemptions} preemptions); kv rail walked "
        f"{report.kv_voltages[0]:.2f} -> {kv_rail.voltage:.2f} V "
        f"({'locked' if kv_rail.locked else 'walking'})"
    )
    for rid in sorted(report.outputs):
        st = report.request_stats[rid]
        toks = report.outputs[rid]
        print(
            f"  req {rid}: prompt={len(stream[rid][0])}t budget={stream[rid][1]}t "
            f"-> {toks[:6].tolist()}{'...' if len(toks) > 6 else ''} "
            f"(cache scrubs: corrected={st.corrected} detected={st.detected})"
        )
    print(
        f"kv cache telemetry: {report.kv_stats.corrected} corrected / "
        f"{report.kv_stats.detected} detected over {report.kv_stats.words} "
        f"scrubbed words; power with kv rail: "
        f"{multi.power_report()['bram_w'] * 1e3:.0f} mW BRAM"
    )

    # Pluggable ECC codecs (DESIGN.md §12): pick a scheme per memory domain
    # — here DEC-TED on the MLP arena (corrects double-bit faults SECDED can
    # only flag) — and hand every rail an escalation ladder: on a DED trip
    # the rail steps its code up instead of retreating, then keeps walking.
    # power_report prices the extra check bits ((64+n_check)/72 per domain).
    print("\nper-domain ECC codecs + DED-triggered escalation:")
    coded = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(
            platform="vc707", ecc=True, voltage=1.0, mode="inline",
            fault_model=FaultModelConfig(mask_source="device"),
            rails=RailsConfig(multi_rail=True, start_v=0.62),
            protection=ProtectionConfig(
                codecs={"mlp": "dected79"},
                escalation=("secded72", "ileave88", "dected79"),
            ),
        ),
        max_len=64,
    )
    volts, hist = coded.autotune_voltage()
    report = coded.power_report()
    for d in sorted(volts):
        actions = [r.action for r in hist[d]]
        print(
            f"  {d:>10}: locked {volts[d]:.2f} V under {report['codecs'][d]:>9} "
            f"({report['check_bits'][d]:2d} check bits, "
            f"{actions.count('escalate')} escalations)"
        )
    print(
        f"BRAM power {report['bram_w'] * 1e3:.0f} mW incl. redundancy "
        f"({100 * report['saving_vs_nominal']:.1f}% saving vs nominal); "
        f"plain multi-rail saved "
        f"{100 * multi.power_report()['saving_vs_nominal']:.1f}%"
    )
    out = coded.generate(prompts, n_tokens=24)
    print(f"token agreement at locked rails: {100 * (out == ref_out).mean():.1f}%")


def share_demo():
    """Prefix sharing + speculative decode (DESIGN.md §16). Run with::

        PYTHONPATH=src python examples/serve_lm_ecc.py --share-demo
    """
    cfg = get_smoke_config("qwen3-0.6b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    eng = ServingEngine(cfg, params, rel=None, max_len=64)

    # A shared-heavy stream: 8 requests whose prompts share a 24-token prefix
    # (3 full pages at page_tokens=8) plus a private 4-token suffix. The
    # first wave of 2 lanes prefills and registers the prefix pages in the
    # trie; every later admission looks them up, bumps their refcount, and
    # prefills only the suffix — the shared pages are physically scrubbed
    # once per interval no matter how many lanes read them.
    prefix = rng.integers(0, cfg.vocab, size=(24,)).astype(np.int32)
    stream = [
        (
            np.concatenate(
                [prefix, rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32)]
            ),
            8,
        )
        for _ in range(8)
    ]
    print("prefix-sharing copy-on-write KV pages:")
    private = eng.serve(stream, n_lanes=2, scrub_interval=4)
    shared = eng.serve(stream, n_lanes=2, scrub_interval=4, share_prefix=True)
    identical = all(
        np.array_equal(private.outputs[r], shared.outputs[r])
        for r in private.outputs
    )
    print(
        f"  served {len(shared.outputs)} requests, "
        f"{shared.prefix_hit_tokens} prompt tokens prefilled from the trie; "
        f"outputs bit-identical to private serve: {identical}"
    )
    assert identical, "shared serve must be bit-identical to private at nominal"

    # Speculative decode: a draft model proposes K tokens per dispatch and
    # the target verifies the whole block in one chunked forward; page
    # commits happen only for accepted tokens. With the target as its own
    # draft every block is fully accepted; emitted tokens are exactly the
    # greedy rollout either way.
    spec = eng.serve(
        stream, n_lanes=2, scrub_interval=4, share_prefix=True,
        speculative=4, draft_params=params, draft_cfg=cfg,
    )
    identical = all(
        np.array_equal(private.outputs[r], spec.outputs[r])
        for r in private.outputs
    )
    print(
        f"speculative decode (K=4, self-draft): {spec.spec_emitted} tokens "
        f"over {spec.spec_dispatches} verify dispatches "
        f"({spec.spec_emitted / max(spec.spec_dispatches, 1):.1f} accepted/block); "
        f"exactly the greedy rollout: {identical}"
    )
    assert identical, "speculative serve must emit exactly the greedy rollout"


def trace_demo(out_dir=None):
    """Reliability flight recorder (docs/OBSERVABILITY.md): serve a small
    stream with the trace recorder attached, then export + validate the run
    timeline in every format. Run with::

        PYTHONPATH=src python examples/serve_lm_ecc.py --trace-demo [DIR]
    """
    import json
    import os
    import tempfile

    from repro.obs import TraceRecorder, read_jsonl, validate_events

    cfg = get_smoke_config("qwen3-0.6b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    rec = TraceRecorder()
    eng = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(
            platform="vc707", ecc=True, voltage=1.0, mode="inline",
            rails=RailsConfig(multi_rail=True, start_v=0.62),
        ),
        max_len=64, recorder=rec,
    )
    eng.autotune_voltage(max_rounds=6)  # rail_step / escalation events
    prompts = rng.integers(0, cfg.vocab, size=(4, 8)).astype(np.int32)
    stream = [
        (prompts[i % 4][: 4 + (3 * i) % 5], 6 + (7 * i) % 13) for i in range(6)
    ]
    report = eng.serve(
        stream, n_lanes=2, page_tokens=8, scrub_interval=4,
        walk_kv=True, kv_voltage=0.60,
    )

    out_dir = out_dir or tempfile.mkdtemp(prefix="repro_trace_")
    os.makedirs(out_dir, exist_ok=True)
    jsonl = os.path.join(out_dir, "trace.jsonl")
    rec.to_jsonl(jsonl)
    n = validate_events(read_jsonl(jsonl))  # schema + causal-order check
    chrome = os.path.join(out_dir, "trace.json")
    rec.to_chrome_trace(chrome)
    with open(chrome) as f:
        ct = json.load(f)
    assert ct["traceEvents"], "chrome trace must not be empty"
    print(
        f"served {len(report.outputs)} requests in {report.steps} steps; "
        f"{n} validated trace events -> {jsonl}"
    )
    print(f"chrome trace ({len(ct['traceEvents'])} entries) -> {chrome}")
    print()
    print(rec.summary_markdown())


def mesh_demo():
    """Mesh-sharded serving (DESIGN.md §13): every data-parallel replica is
    its own chip — own fault population, own rails. Run with forced host
    devices, e.g.::

        XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
            PYTHONPATH=src python examples/serve_lm_ecc.py --mesh-demo
    """
    from repro.launch.mesh import make_reliability_mesh

    cfg = get_smoke_config("qwen3-0.6b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    mesh = make_reliability_mesh()
    n = mesh.shape["data"]
    print(f"mesh serving on {n} reliability shards (policy=per_shard):")
    eng = ServingEngine(
        cfg, params,
        rel=ReliabilityConfig(
            platform="vc707", ecc=True, voltage=1.0, mode="inline",
            fault_model=FaultModelConfig(mask_source="device"),
            rails=RailsConfig(
                multi_rail=True, policy="per_shard", start_v=0.60
            ),
        ),
        max_len=64, mesh=mesh,
    )
    schedules, _ = eng.autotune_voltage(max_rounds=12)
    stream = [
        (rng.integers(1, cfg.vocab, size=int(s)).astype(np.int32), int(b))
        for s, b in zip(rng.integers(3, 9, size=3 * n), rng.integers(4, 10, size=3 * n))
    ]
    report = eng.serve(stream, n_lanes=2, scrub_interval=2, walk_kv=True)
    for s in range(n):
        st = report.kv_stats_by_shard[s]
        rails = ", ".join(f"{d[:4]}={v:.2f}" for d, v in sorted(eng.rails[s].items()))
        print(f"  chip {s}: {rails} | kv scrubs corrected={st.corrected} "
              f"detected={st.detected}")
    pr = eng.power_report()
    print(
        f"served {len(report.outputs)} requests across {n} chips "
        f"({report.steps} dispatch steps, {report.preemptions} preemptions); "
        f"fleet BRAM {pr['bram_w'] * 1e3:.0f} mW, "
        f"{100 * pr['saving_vs_nominal']:.1f}% saving vs nominal"
    )


if __name__ == "__main__":
    import sys

    if "--mesh-demo" in sys.argv:
        mesh_demo()
    elif "--share-demo" in sys.argv:
        share_demo()
    elif "--trace-demo" in sys.argv:
        rest = [a for a in sys.argv[1:] if not a.startswith("--")]
        trace_demo(rest[0] if rest else None)
    else:
        main()
