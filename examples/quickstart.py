"""Quickstart: the paper's mechanism in 60 lines.

1. Put an array into an ECC-protected "BRAM" voltage domain.
2. Undervolt below V_min — faults appear at the calibrated exponential rate.
3. Read through the SECDED decoder: >90% corrected, ~7% detected.
4. Let the DED-canary controller find the minimum safe voltage at runtime.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    EccMemoryDomain,
    FaultStats,
    PLATFORMS,
    UndervoltController,
    voltage,
)

rng = np.random.default_rng(0)
weights = rng.standard_normal((512, 1024)).astype(np.float32)

# 1. Write into the domain (SECDED(72,64)-encoded word planes).
dom = EccMemoryDomain(platform="vc707", seed=42)
dom.write("weights", weights)

# 2-3. Sweep the rail through the critical region.
prof = PLATFORMS["vc707"]
print(f"V_nom={prof.v_nom} V_min={prof.v_min} V_crash={prof.v_crash} "
      f"(guardband {100 * prof.guardband:.0f}%)")
for v in (1.0, 0.61, 0.58, 0.56, 0.54):
    out, stats = dom.read("weights", voltage=v)
    wrong = int((np.asarray(out) != weights).sum())
    print(
        f"V={v:.2f}: faulty_words={stats.faulty_words:5d} "
        f"corrected={stats.corrected:5d} detected={stats.detected:4d} "
        f"silent={stats.silent:3d} wrong_values={wrong:5d} "
        f"bram_power={voltage.bram_power(v, ecc=True):.3f} W"
    )

# 4. Runtime undervolting: lower until the first DED event, then lock.
ctrl = UndervoltController(prof, step_v=0.01)
while not ctrl.locked:
    dom.stats = FaultStats()
    _, stats = dom.read("weights", voltage=ctrl.voltage)
    ctrl.update(stats)
print(
    f"controller locked at {ctrl.voltage:.2f} V "
    f"({100 * (1 - voltage.bram_power(ctrl.voltage, ecc=True) / voltage.bram_power(1.0)):.1f}% "
    f"BRAM power saving vs nominal, zero uncorrected faults)"
)
