"""End-to-end training driver: fault-tolerant trainer on a reduced LM.

Demonstrates the production loop on CPU scale: deterministic data pipeline,
periodic SECDED-protected checkpoints, a mid-run simulated node failure with
automatic restore+replay, straggler monitoring, and (optionally) the
int8+error-feedback compressed-gradient pure-DP step.

Run: PYTHONPATH=src python examples/train_lm.py --arch qwen3-0.6b --steps 200
"""

import argparse
import tempfile

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import FaultInjected, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--fail-at", type=int, default=120,
                    help="simulate a node failure at this step (-1 = off)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    dc = DataConfig(vocab=cfg.vocab, global_batch=args.batch, seq_len=args.seq,
                    n_codebooks=cfg.n_codebooks)
    tc = TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        remat=None,
    )

    armed = {"on": args.fail_at >= 0}

    def chaos(step):
        if armed["on"] and step == args.fail_at:
            armed["on"] = False
            print(f"*** simulated node failure at step {step} ***")
            raise FaultInjected("node lost")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tr = Trainer(
            cfg, tc, TokenPipeline(dc), ckpt_dir,
            ckpt_every=25, ecc_checkpoints=True, fault_hook=chaos,
            straggler_hook=lambda ev: print(
                f"straggler at step {ev.step}: {ev.seconds:.2f}s vs median {ev.median:.2f}s"
            ),
        )
        hist = tr.run(args.steps)
        losses = [h["loss"] for h in hist if "loss" in h]
        print(
            f"\narch={cfg.name} steps={len(losses)} "
            f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
            f"recoveries={tr.recoveries} stragglers={len(tr.straggler.events)}"
        )
        assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
