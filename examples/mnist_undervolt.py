"""End-to-end reproduction of the paper's §IV NN-accelerator case study.

Trains the MLP accelerator on the synthetic-MNIST task, stores int8 weights
SECDED-encoded in the VC707 BRAM domain, undervolts V_CCBRAM from nominal to
V_crash, and reports classification error + power with and without ECC —
paper Fig. 3 as a table.

Run: PYTHONPATH=src python examples/mnist_undervolt.py [--steps 600]
"""

import argparse

import numpy as np

from repro.core import voltage
from repro.core.nn_accel import EccMLP
from repro.data import mnist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--platform", default="vc707")
    args = ap.parse_args()

    xtr, ytr = mnist.make_dataset(20000, split="train")
    xte, yte = mnist.make_dataset(4000, split="test")
    mlp = EccMLP((784, 256, 128, 10), platform=args.platform)
    print("training the accelerator's MLP ...")
    loss = mlp.train(xtr, ytr, steps=args.steps)
    err0 = mlp.error_rate(xte, yte)
    print(f"train loss {loss:.4f}; fault-free error {100 * err0:.2f}% (paper 2.56%)\n")

    prof = voltage.PLATFORMS[args.platform]
    print(f"{'V':>5} | {'err ECC':>8} | {'err noECC':>9} | {'faulty words':>12} "
          f"| {'accel power':>11} | {'BRAM saving vs nom':>18}")
    vs = [prof.v_nom] + list(np.round(np.arange(prof.v_min, prof.v_crash - 1e-9, -0.01), 3))
    for v in vs:
        mlp.set_voltage(float(v), ecc=True)
        e1 = mlp.error_rate(xte, yte)
        fw = mlp.stats.faulty_words
        p = mlp.power_w()
        mlp.set_voltage(float(v), ecc=False)
        e0 = mlp.error_rate(xte, yte)
        sav = 1 - voltage.bram_power(float(v), ecc=True) / voltage.bram_power(prof.v_nom)
        print(f"{v:5.2f} | {100 * e1:7.2f}% | {100 * e0:8.2f}% | {fw:12d} "
              f"| {p:9.2f} W | {100 * sav:17.1f}%")

    mlp.set_voltage(prof.v_crash, ecc=True)
    e1 = mlp.error_rate(xte, yte)
    print(
        f"\n@V_crash with ECC: error {100 * e1:.2f}% (+{100 * (e1 - err0):.2f} vs fault-free; "
        f"paper +0.56); accelerator power saving nom->crash "
        f"{100 * (1 - voltage.accelerator_power(prof.v_crash) / voltage.accelerator_power(prof.v_nom, ecc=False)):.1f}% "
        f"(paper 25.2%)"
    )


if __name__ == "__main__":
    main()
